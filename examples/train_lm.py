"""End-to-end training driver: data pipeline -> model -> AdamW -> fault-
tolerant trainer with periodic checkpoints.

Profiles:
  --size small   ~5M params  (default; a few minutes for 200 steps on CPU)
  --size 100m    ~100M params (the assignment's reference scale; run a few
                  hundred steps on real accelerators)

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200

``--tp-demo`` first runs one explicit tensor-parallel transformer block
over all visible devices through the context-scoped collectives API
(``repro.comms.api.comm_context`` + ``models.model.transformer_block_tp``)
and checks it against the single-device reference block — the same
machinery `launch/train.py --zero1 explicit` and `launch/perf.py
--tp-block` use at scale.  Spin up fake devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--arch llama4-scout-17b-a16e`` (or ``arctic-480b``) trains the reduced
registry config instead of the example profile; add ``--expert-parallel``
to set the MoE ``expert_axis`` knob (``repro.configs.expert_parallel`` —
no config hand-editing) and first demo the expert-parallel block: experts
sharded over all visible devices, dispatch/combine through the
context-planned ``api.all_to_all``, checked against the all-experts-local
reference.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ModelConfig, expert_parallel, get_config, list_archs
from repro.configs import reduced as reduce_cfg
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_params
from repro.optim import OptimizerConfig, adamw_init
from repro.runtime import Trainer, TrainerConfig

PROFILES = {
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024, vocab_size=4096, seq=256, batch=4),
    "100m": dict(num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32768, seq=1024, batch=32),
}


def build_config(size: str) -> ModelConfig:
    p = dict(PROFILES[size])
    p.pop("seq"), p.pop("batch")
    return ModelConfig(
        name=f"example-{size}", family="dense", dtype="float32",
        remat=False, qkv_bias=False, qk_norm=True, **p,
    )


def tp_demo():
    """One explicit-TP transformer block on the context-scoped API vs the
    reference block, over every visible device."""
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import shard_map
    from repro.comms import comm_context, make_factorized_mesh
    from repro.models.model import (
        _layer_init, transformer_block_ref, transformer_block_tp,
        tp_block_specs,
    )

    n = len(jax.devices())
    cfg = dataclasses.replace(
        build_config("small"), num_heads=n, num_kv_heads=n, head_dim=16,
        d_model=16 * n, d_ff=32 * n, qk_norm=False)
    layer = _layer_init(jax.random.key(0), cfg, dtype=jnp.float32)
    B, S = 2, 4 * n
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    ref = transformer_block_ref(layer, cfg, x, positions=pos)

    mesh = make_factorized_mesh([n], ["tp"])
    with comm_context(mesh, ("tp",)) as ctx:
        for sp in (False, True):
            x_spec, l_spec = tp_block_specs(layer, ("tp",),
                                            sequence_parallel=sp)
            fn = shard_map(
                lambda lx, ll, sp=sp: transformer_block_tp(
                    ll, cfg, lx, positions=pos, sequence_parallel=sp),
                mesh=mesh, in_specs=(x_spec, l_spec), out_specs=x_spec)
            got = jax.jit(fn)(x, layer)
            ok = np.allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
            print(f"[tp-demo] {'SP' if sp else 'TP'} block over {n} device(s) "
                  f"== reference: {ok}")
            assert ok
        print(f"[tp-demo] context cached {len(ctx.plans())} CollectivePlans "
              f"({ctx.cache_stats})")


def moe_demo(arch: str):
    """The expert-parallel MoE block on the context-scoped API vs the
    all-experts-local reference, experts sharded over every visible
    device (``models.moe`` EP path through ``api.all_to_all``)."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.comms import comm_context, make_factorized_mesh
    from repro.models.moe import moe_block, moe_init

    n = len(jax.devices())
    cfg = reduce_cfg(get_config(arch))
    if cfg.moe is None:
        raise SystemExit(f"--expert-parallel: {arch} has no MoE block")
    # experts must divide over the device axis; pad the reduced count up
    E = ((cfg.moe.num_experts + n - 1) // n) * n
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=E))
    cfg_ep = expert_parallel(cfg, axis="ep")

    p = moe_init(jax.random.key(0), cfg_ep, dtype=jnp.float32)
    B, S = 2 * n, 8
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)
    ref = jnp.concatenate(
        [moe_block(p, cfg, x[i * 2:(i + 1) * 2])[0] for i in range(n)], axis=0)

    mesh = make_factorized_mesh([n], ["ep"])
    with comm_context(mesh, ("ep",)) as ctx:
        fn = shard_map(lambda pp, xx: moe_block(pp, cfg_ep, xx)[0],
                       mesh=mesh, in_specs=(P(), P("ep")), out_specs=P("ep"))
        got = jax.jit(fn)(p, x)
        ok = np.allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
        a2a = [pl for pl in ctx.plans() if pl.collective == "a2a"]
        print(f"[moe-demo] {arch} EP block ({E} experts over {n} device(s)) "
              f"== all-experts-local reference: {ok}")
        print(f"[moe-demo] context cached {len(ctx.plans())} plans "
              f"({len(a2a)} a2a, {ctx.cache_stats})")
        assert ok
        assert n == 1 or a2a, "EP dispatch did not go through api.all_to_all"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(PROFILES), default="small")
    ap.add_argument("--arch", choices=list_archs(), default=None,
                    help="train this registry arch (reduced config) instead "
                         "of the example profile")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="with a MoE --arch: set the expert_axis knob on the "
                         "training config and demo the expert-parallel block "
                         "(context-planned all-to-all dispatch) first")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--tp-demo", action="store_true",
                    help="run the explicit-TP block demo (context-scoped "
                         "collectives API) before training")
    args = ap.parse_args()

    if args.tp_demo:
        tp_demo()
    if args.expert_parallel:
        if not args.arch:
            raise SystemExit("--expert-parallel needs --arch (a MoE arch, "
                             "e.g. llama4-scout-17b-a16e or arctic-480b)")
        moe_demo(args.arch)

    prof = PROFILES[args.size]
    if args.arch:
        cfg = dataclasses.replace(reduce_cfg(get_config(args.arch)),
                                  dtype="float32")
        if args.expert_parallel:
            # the knob, no hand-editing: dormant under the plain-jit Trainer
            # (no bound axis), live in launch/train.py --zero1 explicit
            cfg = expert_parallel(cfg, axis="data")
        prof = dict(prof, seq=64, batch=4)
    else:
        cfg = build_config(args.size)
    n_params_est = (
        cfg.vocab_size * cfg.d_model * 2
        + cfg.num_layers * (2 * cfg.d_model * (cfg.q_dim + cfg.kv_dim)
                            + 3 * cfg.d_model * cfg.d_ff)
    )
    print(f"config {cfg.name}: ~{n_params_est/1e6:.0f}M params, "
          f"seq={prof['seq']}, batch={prof['batch']}, {len(jax.devices())} device(s)")

    params = init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    pipe = SyntheticLMPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=prof["seq"],
        global_batch=prof["batch"],
    )).start()

    trainer = Trainer(
        cfg,
        OptimizerConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_interval=50,
                      ckpt_dir=args.ckpt_dir),
        params=params, opt_state=opt_state, pipeline=pipe,
    )
    t0 = time.time()
    out = trainer.run()
    pipe.stop()
    dt = time.time() - t0
    losses = out["losses"]
    print(f"steps={out['final_step']} restarts={out['restarts']} "
          f"time={dt:.1f}s ({dt/max(out['final_step'],1):.2f}s/step)")
    print(f"loss: first={losses[0]:.4f} min={min(losses):.4f} "
          f"last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not reduce the loss"
    print("OK: loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
