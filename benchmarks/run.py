"""Benchmark harness — one function per paper table/figure.

(The tp_block section spins up 8 fake host devices; the flag must be set
before jax initializes, hence the setdefault at import.)

Prints ``name,us_per_call,derived`` CSV rows: ``us_per_call`` measures the
scheduling computation itself (OpTree is a scheduling algorithm — its own
cost matters); ``derived`` carries the paper-comparable numbers.

  table1  — Table I step counts @ N=1024, w=64 (+ printed-paper deltas)
  fig4    — depth sweep, optimal k per N in {512..4096}
  fig5    — message-size sweep @ w=64, N in {1024, 2048}: time + reductions
  fig6    — wavelength sweep @ N=1024, w in {96, 128}
  schedule_level — transmission-level schedules vs closed forms (small N)
  planner — TPU-adaptation: staged-plan times vs flat/ring on the v5e model
  collectives — staged-RS/AR plans (all-gather duals) + chunked-overlap decision
  perhop  — hop-schedule mode decisions + collective-matmul fusion model
  ir      — unified CollectivePlan IR: one engine plan priced electrical +
            optical and validated in the conflict-checked simulator
  order_search — cross-world stage-order search on an asymmetric links
            table: the order the optical (Eq. 3 / RWA) pricer picks vs the
            electrical winner, with the winner's price asserted equal to
            the conflict-checked simulator's wall time
  latency_regime — latency-regime plans: recursive-doubling exchange
            chains strictly beat every ring mode at KiB shards under both
            cost worlds (and lose at MiB), with the crossover in between
            and price==simulate healthy + degraded
  a2a     — all-to-all as a first-class collective: cross-world order
            search on the 2x3 asymmetric table (electrical order-invariant,
            optical strictly prefers an order at low w — a pure-optical
            flip, price==simulate via the exchange item model) + bit-
            identity vs the XLA one-shot lax.all_to_all in every plan mode
  tp_block — explicit-TP transformer block on context collectives
            (repro.comms.api) vs the GSPMD path: modeled electrical +
            optical + measured, off the same CollectivePlan objects
  duality — optics-model step counts for RS/AR vs the all-gather numbers
            (+ per-stage wall-time attribution)
  serving — cluster routing policies (JSQ / greedy-cost / max-flow vs
            round-robin) p50/p99 on a heterogeneous two-replica cluster in
            the event-driven serving simulator, both cost worlds
  roofline — §Roofline table from runs/dryrun (skips if absent)
"""
import os
import sys
import time
from pathlib import Path

# only affects the CPU host platform (tp_block's fake-device mesh); real
# accelerator platforms ignore it and sections keep measuring there
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.configs import optree_paper as paper  # noqa: E402
from repro.core import (  # noqa: E402
    OpTreePlan,
    TERARACK,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    build_ring_schedule,
    eq3_time,
    validate_schedule,
)
from repro.core import steps as S  # noqa: E402
from repro.core.planner import (  # noqa: E402
    DCN_LINK,
    ICI_LINK,
    choose_hop_schedule,
    matmul_block_time,
    plan_all_reduce,
    plan_axis_order,
    plan_collective_matmul,
    plan_reduce_scatter_order,
    plan_staged_allgather,
)
from repro.optics import simulate  # noqa: E402
from repro.optics.comparison import compare_algorithms  # noqa: E402


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _timeit(fn, reps=5):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps * 1e6, out


# --------------------------------------------------------------------------
def table1():
    n, w = paper.TABLE1_N, paper.TABLE1_W
    us, t = _timeit(lambda: S.table1(n, w))
    paper_vals = {"Ring": 1023, "NE": 512, "WRHT": 259, "One-Stage": 128,
                  "OpTree": 70}
    ours = {
        "Ring": S.ring_steps(n), "NE": S.neighbor_exchange_steps(n),
        "WRHT": S.wrht_steps_formula(n, w), "One-Stage": S.one_stage_steps(n, w),
        "OpTree": S.optree_optimal_steps(n, w)[1],
    }
    for k in paper_vals:
        match = "MATCH" if ours[k] == paper_vals[k] else "DIFFERS(see DESIGN.md)"
        _row(f"table1/{k}", us, f"steps={ours[k]};paper={paper_vals[k]};{match}")


def fig4():
    for n in paper.FIG4_NODES:
        def sweep():
            return {k: S.optree_steps_thm1(n, k, paper.TABLE1_W)
                    for k in paper.FIG4_DEPTHS}
        us, by_k = _timeit(sweep)
        k_opt = min(by_k, key=by_k.get)
        t_opt = eq3_time(paper.SYSTEM, paper.FIG4_MESSAGE_BYTES, by_k[k_opt])
        norm = ";".join(f"k{k}={by_k[k]/by_k[k_opt]:.3f}" for k in by_k)
        _row(f"fig4/N{n}", us, f"k_opt={k_opt};steps={by_k[k_opt]};"
                               f"t_opt_ms={t_opt*1e3:.2f};norm:{norm}")
    # paper: optimal depths 6,6,7,8; one-stage avg reduction 96.85%
    reds = []
    for n in paper.FIG4_NODES:
        _, s_opt = S.optree_optimal_steps(n, paper.TABLE1_W)
        reds.append(1 - s_opt / S.one_stage_steps(n, paper.TABLE1_W))
    _row("fig4/one_stage_reduction", 0.0,
         f"avg={np.mean(reds)*100:.2f}%;paper=96.85%")


def _compare(n, w, msgs, tag):
    algos = {
        "optree": lambda: S.optree_optimal_steps(n, w)[1],
        "wrht_formula": lambda: S.wrht_steps_formula(n, w),
        "wrht_paper": lambda: S.wrht_steps_paper_table(n, w),
        "ring": lambda: S.ring_steps(n),
        "ne": lambda: S.neighbor_exchange_steps(n),
        "one_stage": lambda: S.one_stage_steps(n, w),
    }
    steps = {k: f() for k, f in algos.items()}
    for m in msgs:
        times = {k: eq3_time(paper.SYSTEM, m, v) * 1e3
                 for k, v in steps.items() if v is not None}
        _row(f"{tag}/msg{m//2**20}M", 0.0,
             ";".join(f"{k}={v:.2f}ms" for k, v in times.items()))
    red = {k: 1 - steps["optree"] / steps[k]
           for k in ("ring", "ne") if steps.get(k)}
    if steps.get("wrht_paper"):
        red["wrht_paper"] = 1 - steps["optree"] / steps["wrht_paper"]
    _row(f"{tag}/reductions", 0.0,
         ";".join(f"vs_{k}={v*100:.2f}%" for k, v in red.items()))


def fig5():
    for n in paper.FIG5_NODES:
        _compare(n, paper.TABLE1_W, paper.FIG5_MESSAGES, f"fig5/N{n}")
    # paper claims (avg over both N): ring 92.76%, ne 85.54%, wrht 56.36%
    ring_avg = np.mean([1 - S.optree_optimal_steps(n, 64)[1] / S.ring_steps(n)
                        for n in paper.FIG5_NODES])
    ne_avg = np.mean([1 - S.optree_optimal_steps(n, 64)[1] /
                      S.neighbor_exchange_steps(n) for n in paper.FIG5_NODES])
    _row("fig5/claims", 0.0,
         f"ring_avg={ring_avg*100:.2f}%(paper 92.76);ne_avg={ne_avg*100:.2f}%"
         f"(paper 85.54);wrht=see DESIGN.md caveat")


def fig6():
    for w in paper.FIG6_WAVELENGTHS:
        _compare(paper.TABLE1_N, w, paper.FIG6_MESSAGES, f"fig6/w{w}")
    ring_avg = np.mean([
        1 - S.optree_optimal_steps(1024, w)[1] / S.ring_steps(1024)
        for w in paper.FIG6_WAVELENGTHS
    ])
    ne_avg = np.mean([
        1 - S.optree_optimal_steps(1024, w)[1] / S.neighbor_exchange_steps(1024)
        for w in paper.FIG6_WAVELENGTHS
    ])
    _row("fig6/claims", 0.0,
         f"ring_avg={ring_avg*100:.2f}%(paper 95.84);ne_avg={ne_avg*100:.2f}%"
         f"(paper 91.69)")


def schedule_level():
    """Transmission-level schedules (full RWA) vs the closed forms."""
    cases = [(16, (4, 4), 2), (64, (4, 4, 4), 8), (81, (3, 3, 3, 3), 16),
             (64, (8, 8), 64), (128, (2, 4, 4, 4), 64)]
    for n, factors, w in cases:
        plan = OpTreePlan(n, factors)

        def build():
            sched = build_optree_schedule(plan, w)
            validate_schedule(sched)
            return sched

        us, sched = _timeit(build, reps=1)
        rep = simulate(sched, TERARACK, 4 * 2**20)
        formula = S.optree_steps_exact(plan, w)
        _row(f"schedule/optree_N{n}_k{len(factors)}_w{w}", us,
             f"steps={rep.steps};formula={formula};txs={rep.transmissions};"
             f"time_ms={rep.time_s*1e3:.2f}")
    for n, w in [(16, 2), (32, 8), (64, 64)]:
        for name, builder in (("one_stage", build_one_stage_schedule),
                              ("ring", build_ring_schedule),
                              ("ne", build_ne_schedule)):
            us, sched = _timeit(lambda b=builder: b(n, w), reps=1)
            validate_schedule(sched)
            _row(f"schedule/{name}_N{n}_w{w}", us, f"steps={sched.num_steps}")


def planner():
    """TPU adaptation: staged-plan estimated times on the v5e link model."""
    for axis, shard in [(256, 4 * 2**20), (256, 64 * 2**10), (512, 1 * 2**20)]:
        us, plan = _timeit(lambda a=axis, s=shard: plan_staged_allgather(a, s))
        flat = (axis - 1) * (ICI_LINK.alpha_s + shard / ICI_LINK.bandwidth_bytes)
        _row(f"planner/axis{axis}_shard{shard//1024}K", us,
             f"factors={plan.factors};t_staged_us={plan.total_time_s*1e6:.1f};"
             f"t_flat_ring_us={flat*1e6:.1f};"
             f"speedup={flat/plan.total_time_s:.2f}x")
    us, plan = _timeit(
        lambda: plan_axis_order([(2, DCN_LINK), (16, ICI_LINK)], 8 * 2**20)
    )
    _row("planner/pod_order", us,
         f"order={[s.link.name for s in plan.stages]};"
         f"t_us={plan.total_time_s*1e6:.1f};slow_axis_first="
         f"{plan.stages[0].link.name == 'dcn'}")


def collectives():
    """Staged-RS/AR plans (the all-gather duals) vs XLA single-shot models,
    plus the chunked-overlap decision."""
    axes = [(2, DCN_LINK), (16, ICI_LINK)]
    n = int(np.prod([f for f, _ in axes]))
    for shard in (64 * 2**10, 1 * 2**20, 8 * 2**20):
        us_rs, rs = _timeit(lambda s=shard: plan_reduce_scatter_order(axes, s))
        us_ar, ar = _timeit(lambda s=shard: plan_all_reduce(axes, s))
        ag = plan_axis_order(axes, shard)
        # flat single-shot models: one stage over all N devices on the slow link
        flat_rs = (n - 1) * (DCN_LINK.alpha_s + shard / DCN_LINK.bandwidth_bytes)
        _row(f"collectives/rs_shard{shard//1024}K", us_rs,
             f"order={[s.link.name for s in rs.stages]};"
             f"steps={sum(s.factor - 1 for s in rs.stages)};"
             f"t_us={rs.total_time_s*1e6:.1f};flat_us={flat_rs*1e6:.1f};"
             f"chunks={rs.num_chunks};t_chunked_us={rs.pipelined_time_s*1e6:.1f};"
             f"slow_axis_last={rs.stages[-1].link.name == 'dcn'};"
             f"dual_of_ag={[s.factor for s in rs.stages] == [s.factor for s in reversed(ag.stages)]}")
        _row(f"collectives/ar_shard{shard//1024}K", us_ar,
             f"steps={sum(s.factor - 1 for s in ar.reduce_scatter.stages) + sum(s.factor - 1 for s in ar.all_gather.stages)};"
             f"t_us={ar.total_time_s*1e6:.1f};"
             f"t_chunked_us={ar.pipelined_time_s*1e6:.1f};"
             f"chunks={ar.num_chunks}")


def perhop():
    """Hop-schedule decisions (one-shot vs chunked vs per-hop ppermute
    rings) + the collective-matmul fusion model, same LinkSpecs as the
    ``collectives`` section."""
    axes = [(2, DCN_LINK), (16, ICI_LINK)]
    for shard in (64 * 2**10, 1 * 2**20, 8 * 2**20):
        ag = plan_axis_order(axes, shard)
        links = [s.link for s in ag.stages]
        us, hs = _timeit(lambda f=ag.factors, l=links, s=shard:
                         choose_hop_schedule(f, l, s, collective="ag"))
        _row(f"perhop/ag_shard{shard//1024}K", us,
             f"mode={hs.mode};stage_modes={'/'.join(hs.stage_modes)};"
             f"oneshot_us={hs.oneshot_time_s*1e6:.1f};"
             f"chunked_us={hs.chunked_time_s*1e6:.1f}(C={hs.num_chunks});"
             f"perhop_us={hs.perhop_time_s*1e6:.1f};"
             f"exposed_KB={hs.exposed_bytes/2**10:.0f};"
             f"hidden_KB={hs.hidden_bytes/2**10:.0f}")
        us_ar, ar = _timeit(lambda s=shard: choose_hop_schedule(
            [st.factor for st in reversed(ag.stages)],
            [st.link for st in reversed(ag.stages)], s, collective="ar"))
        _row(f"perhop/ar_shard{shard//1024}K", us_ar,
             f"mode={ar.mode};perhop_us={ar.perhop_time_s*1e6:.1f};"
             f"oneshot_us={ar.oneshot_time_s*1e6:.1f}")
    # collective-matmul fusion: v5e-roofline block matmul vs the hop time
    # (bf16 FFN-entry shapes: rows = per-block tokens, 4096 -> 16384 proj)
    for rows, tag in ((64, "skinny"), (1024, "wide")):
        t_blk = matmul_block_time(rows, 4096, 16384)
        us, fm = _timeit(lambda t=t_blk: plan_collective_matmul(
            (2, 16), (DCN_LINK, ICI_LINK), rows * 4096 * 2, t))
        _row(f"perhop/fusion_{tag}", us,
             f"fuse={fm.fuse};fused_us={fm.fused_time_s*1e6:.1f};"
             f"unfused_us={fm.unfused_time_s*1e6:.1f};"
             f"hidden_comm_us={fm.hidden_comm_s*1e6:.1f}")


def duality():
    """Paper-model step counts for the reduce-scatter dual + all-reduce
    (optics backend): RS steps equal AG steps by time-reversal symmetry.
    Per-stage attribution (AlgoResult.stage_times_s) shows where the wall
    time goes — OpTree's slow first stage vs the cheap deep stages."""
    for coll in ("all-gather", "reduce-scatter", "all-reduce"):
        res = compare_algorithms(
            paper.TABLE1_N, paper.TABLE1_W, 4 * 2**20, paper.SYSTEM,
            ("optree", "ring", "ne", "one-stage"), collective=coll,
        )
        _row(f"duality/{coll}", 0.0,
             ";".join(f"{k}={v.steps}steps/{v.time_s*1e3:.2f}ms"
                      for k, v in res.items()))
        ot = res["optree"]
        _row(f"duality/{coll}/optree_stages", 0.0,
             f"stage_steps={list(ot.stage_steps)};stage_ms="
             + "/".join(f"{t*1e3:.2f}" for t in ot.stage_times_s))


def ir():
    """Unified CollectivePlan IR: ONE plan object from the engine planner,
    priced under both cost worlds (LinkSpec electrical + optical Eq. 3) and
    validated step-accurately in the conflict-checked simulator."""
    import dataclasses

    from repro.core import price, schedule_from_ir
    from repro.core.cost_model import TERARACK

    axes = [(2, DCN_LINK), (16, ICI_LINK)]
    for coll in ("ag", "rs", "ar"):
        planner_fn = plan_axis_order if coll == "ag" else plan_reduce_scatter_order
        for shard in (64 * 2**10, 4 * 2**20):
            base = planner_fn(axes, shard)
            links = [s.link for s in base.stages]

            def build(f=base.factors, l=links, s=shard, c=coll):
                hs = choose_hop_schedule(f, l, s, collective=c)
                return hs.to_ir()

            us, plan = _timeit(build)
            elec = price(plan)
            sys_small = dataclasses.replace(TERARACK, n_nodes=plan.n)
            opt = price(plan, sys_small)
            sched = schedule_from_ir(plan, sys_small.wavelengths)
            rep = simulate(sched, sys_small, plan.shard_bytes)
            assert abs(rep.time_s - opt.total_s) < 1e-12  # one plan, one price
            _row(f"ir/{coll}_shard{shard//1024}K", us,
                 f"mode={plan.mode};factors={list(plan.factors)};"
                 f"stage_modes={'/'.join(plan.stage_modes)};"
                 f"elec_us={elec.total_s*1e6:.1f};"
                 f"optical_us={opt.total_s*1e6:.1f}@{opt.steps}steps;"
                 f"sim_steps={rep.steps};txs={rep.transmissions};"
                 f"stage_ms=" + "/".join(f"{t*1e3:.3f}" for t in rep.stage_times_s))


def order_search():
    """Cross-world stage-order search (ISSUE 5 tentpole): on an asymmetric
    LinkSpec table the electrical planner (slow-axis-first AG) and the
    optical Eq.-3/RWA pricer disagree about the stage order — the optical
    winner routes the big factor's hops on the whole ring where the
    wavelength reuse is better.  Asserts the acceptance criterion:
    ``price(plan, optical) == simulate(schedule_from_ir(plan))`` for every
    winner, and the AG order genuinely flips at low wavelength counts."""
    import dataclasses

    from repro.core import price, schedule_from_ir
    from repro.core.planner import LinkSpec, search_stage_orders

    # size-4 axis on the SLOW transport: electrically the AG wants it
    # first (payload smallest there), optically its ring hops are cheaper
    # as stage 1 — the two worlds flip (8-device mesh, w<=2)
    axes = [("a", 2, LinkSpec("fast", 50e9, 1e-6)),
            ("b", 4, LinkSpec("slow", 1e9, 1e-5))]
    flipped_ag = None
    for w in (1, 2, 64):
        sys_w = dataclasses.replace(TERARACK, n_nodes=8, wavelengths=w)
        for coll in ("ag", "rs", "ar"):
            us, srch = _timeit(lambda c=coll, s=sys_w: search_stage_orders(
                axes, 1 * 2**20, collective=c, backend="optical", system=s))
            eb, ob = srch.best_by("electrical"), srch.best_by("optical")
            # acceptance: the winner's optical price IS the simulated time
            rep = simulate(
                schedule_from_ir(ob.plan, sys_w.wavelengths), sys_w,
                ob.plan.shard_bytes, check=True)
            assert abs(rep.time_s - ob.optical_s) < 1e-12, (coll, w)
            assert abs(rep.time_s - price(ob.plan, sys_w).total_s) < 1e-12
            if coll == "ag" and w <= 2:
                flipped_ag = srch.flipped
                assert ob.optical_s < eb.optical_s  # strictly cheaper
            _row(f"order_search/{coll}_w{w}", us,
                 f"elec_order={'>'.join(eb.order)};"
                 f"opt_order={'>'.join(ob.order)};"
                 f"flipped={srch.flipped};"
                 f"elec_pick_opt_us={eb.optical_s*1e6:.1f}@{eb.optical_steps};"
                 f"opt_pick_opt_us={ob.optical_s*1e6:.1f}@{ob.optical_steps};"
                 f"mode={ob.plan.mode}")
    assert flipped_ag, "optical pricer should flip the AG order at low w"


def latency_regime():
    """Latency-regime plans (ISSUE 8): recursive-doubling exchange chains
    for decode-size payloads.  Asserts the acceptance criteria on the
    asymmetric 8-device table: at KiB shards the latency plan is strictly
    cheaper than every ring-mode plan under BOTH cost worlds, at MiB
    shards the ring family wins both, the crossover sits in between, and
    the latency plan's optical price equals the conflict-checked
    simulator byte for byte — healthy AND degraded."""
    import dataclasses

    from repro.core import optical_message_bytes, price, schedule_from_ir
    from repro.core.health import LinkHealth
    from repro.core.planner import (
        LinkSpec,
        latency_crossover_bytes,
        plan_latency_collective,
        search_stage_orders,
    )

    axes = [("a", 2, LinkSpec("fast", 50e9, 1e-6)),
            ("b", 4, LinkSpec("slow", 1e9, 1e-5))]
    w = 2
    sys2 = dataclasses.replace(TERARACK, n_nodes=8, wavelengths=w)
    health = LinkHealth.make(derate={("b", +1): 0.5})

    for coll in ("ag", "rs", "ar"):
        # --- KiB shard: exchange chain beats every ring mode, both worlds
        small = 1 * 2**10
        us, lat = _timeit(lambda c=coll: plan_latency_collective(
            axes, small, collective=c))
        assert lat is not None and all(s.mode == "exchange" for s in lat.stages)
        ring = search_stage_orders(axes, small, collective=coll,
                                   backend="optical", system=sys2,
                                   include_latency=False)
        lat_e, ring_e = price(lat).total_s, price(ring.best_by("electrical").plan).total_s
        lat_o = price(lat, sys2)
        ring_o = ring.best_by("optical").optical_s
        assert lat_e < ring_e, (coll, lat_e, ring_e)   # electrical win
        assert lat_o.total_s < ring_o, (coll, lat_o.total_s, ring_o)
        # price == simulate, healthy then degraded (derated slow axis)
        rep = simulate(schedule_from_ir(lat, w), sys2,
                       optical_message_bytes(lat), check=True)
        assert abs(rep.time_s - lat_o.total_s) < 1e-12, coll
        deg = price(lat, sys2, health=health)
        rep_d = simulate(schedule_from_ir(lat, w, health=health), sys2,
                         optical_message_bytes(lat), check=True, health=health)
        assert abs(rep_d.time_s - deg.total_s) < 1e-12, coll
        assert deg.total_s >= lat_o.total_s * (1 - 1e-12)
        # --- MiB shard: the ring family wins both worlds again
        big = 1 * 2**20
        lat_big = plan_latency_collective(axes, big, collective=coll)
        ring_big = search_stage_orders(axes, big, collective=coll,
                                       backend="optical", system=sys2,
                                       include_latency=False)
        assert price(lat_big).total_s > price(
            ring_big.best_by("electrical").plan).total_s, coll
        assert price(lat_big, sys2).total_s > \
            ring_big.best_by("optical").optical_s, coll
        # --- and the modeled crossover sits strictly between the two
        xover = latency_crossover_bytes(axes, collective=coll)
        assert xover is not None and small < xover < big, (coll, xover)
        _row(f"latency_regime/{coll}", us,
             f"rounds={len(lat.stages)};"
             f"lat_elec_us={lat_e*1e6:.2f};ring_elec_us={ring_e*1e6:.2f};"
             f"lat_opt_us={lat_o.total_s*1e6:.1f}@{lat_o.steps}steps;"
             f"ring_opt_us={ring_o*1e6:.1f};"
             f"degraded_opt_us={deg.total_s*1e6:.1f};"
             f"crossover_B={xover:.0f}")


def a2a():
    """All-to-all as a first-class collective (ISSUE 6).  (1) The cross-
    world order search on the asymmetric 2x3 table: a2a's electrical cost
    is stage-order INVARIANT (every stage moves 1/m of every peer's
    shard), so every candidate prices identically there, while the optical
    RWA step count still depends on the order — at w<=2 the optical winner
    strictly beats the electrical tie-break, a pure-optical flip.  Price ==
    simulate for every winner via the exchange item model
    (``optical_message_bytes``: the (origin,dest) block, shard/n).  (2)
    Duality with the XLA one-shot: ``api.all_to_all`` stays bit-identical
    to ``lax.all_to_all(tiled=True)`` in every plan mode on 8 fake
    devices, with both paths timed."""
    import dataclasses

    from repro.core import optical_message_bytes, price, schedule_from_ir
    from repro.core.planner import LinkSpec, search_stage_orders

    axes23 = [("a", 2, LinkSpec("fast", 50e9, 1e-6)),
              ("b", 3, LinkSpec("slow", 1e9, 1e-5))]
    flipped_low_w = None
    for w in (1, 2, 64):
        sys_w = dataclasses.replace(TERARACK, n_nodes=6, wavelengths=w)
        us, srch = _timeit(lambda s=sys_w: search_stage_orders(
            axes23, 1 * 2**20, collective="a2a", backend="optical", system=s))
        eb, ob = srch.best_by("electrical"), srch.best_by("optical")
        # electrical order-invariance: every candidate the same to 1e-12
        elec = [c.electrical_s for c in srch.candidates]
        assert max(elec) - min(elec) <= 1e-12 * max(elec), "a2a not invariant"
        rep = simulate(schedule_from_ir(ob.plan, w), sys_w,
                       optical_message_bytes(ob.plan), check=True)
        assert abs(rep.time_s - ob.optical_s) < 1e-12, w
        assert abs(rep.time_s - price(ob.plan, sys_w).total_s) < 1e-12
        if w <= 2:
            flipped_low_w = srch.flipped
            assert ob.optical_s < eb.optical_s  # strictly, not a tie-break
        _row(f"a2a/order_w{w}", us,
             f"elec_order={'>'.join(eb.order)};opt_order={'>'.join(ob.order)};"
             f"flipped={srch.flipped};"
             f"opt_us={ob.optical_s*1e6:.1f}@{ob.optical_steps};"
             f"elec_pick_opt_us={eb.optical_s*1e6:.1f}@{eb.optical_steps};"
             f"elec_invariant=True")
    assert flipped_low_w, "a2a order should flip at low w (optical-only pref)"

    # duality vs the XLA one-shot, on fake devices
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.comms import comm_context, make_factorized_mesh
    from repro.comms.api import all_to_all as api_a2a

    if len(jax.devices()) != 8:
        _row("a2a/exec/status", 0.0,
             f"SKIP(need 8 devices, have {len(jax.devices())})")
        return
    mesh = make_factorized_mesh([2, 4], ["a", "b"])
    x = jnp.arange(8 * 512, dtype=jnp.float32)
    xla = jax.jit(shard_map(
        lambda y: lax.all_to_all(y, ("a", "b"), 0, 0, tiled=True),
        mesh=mesh, in_specs=P(("a", "b")), out_specs=P(("a", "b"))))
    want = np.asarray(xla(x))
    us_xla, _ = _timeit(lambda: np.asarray(xla(x)))
    with comm_context(mesh, ("a", "b")) as ctx:
        for mode, chunks in ((None, None), ("oneshot", None),
                             ("chunked", 4), ("perhop", None),
                             ("hybrid", 2)):
            f = jax.jit(lambda y, m=mode, c=chunks: api_a2a(
                y, ctx=ctx, mode=m, num_chunks=c))
            got = np.asarray(f(x))
            assert np.array_equal(got, want), (mode, chunks)
            us, _ = _timeit(lambda f=f: np.asarray(f(x)))
            tag = (mode or "planned") + (f"x{chunks}" if chunks else "")
            _row(f"a2a/exec_{tag}", us,
                 f"bit_identical=True;xla_oneshot_us={us_xla:.0f}")


def tp_block():
    """Explicit-TP transformer block driven entirely by the context-scoped
    collectives API vs the GSPMD path — the ROADMAP "full shard_map
    transformer block" benchmark.  Modeled-electrical, modeled-optical and
    measured wall-clock all come off the SAME CollectivePlan objects the
    context cached while the block ran."""
    from repro.launch.perf import tp_block_bench

    try:
        rows = tp_block_bench("2,4", reps=3)
    except (RuntimeError, ValueError) as e:  # e.g. too few host devices
        _row("tp_block/status", 0.0, f"SKIP({e})")
        return
    for r in rows:
        _row(f"tp_block/{r['variant']}", 0.0,
             f"plans={r['plans']};issued={r['issued']};"
             f"modes={'/'.join(r['modes'])};"
             f"modeled_elec_us={r['modeled_elec_us']:.1f};"
             f"modeled_opt_us={r['modeled_opt_us']:.1f};"
             f"measured_explicit_us={r['measured_tp_us']:.0f};"
             f"measured_gspmd_us={r['measured_gspmd_us']:.0f};"
             f"allclose={r['allclose']}")


def serving():
    """Cluster serving policies (ISSUE 9): JSQ / greedy-cost / max-flow vs
    round-robin p50/p99 on a heterogeneous two-replica config under BOTH
    cost worlds, off the event-driven simulator (seeded Poisson + bursty
    traces; ``us_per_call`` times one full simulation run — the scheduler
    and simulator are themselves scheduling computations).  Asserts the
    acceptance ordering: the cost-model-aware policies strictly beat
    round-robin on p99 for the Poisson trace."""
    from repro.cluster import (ClusterSim, ReplicaSpec, bursty_trace,
                               make_policy, poisson_trace)

    specs = [
        ReplicaSpec.from_times("fast", 4, prefill_token_s=1e-4,
                               decode_step_s=5e-4, link=ICI_LINK),
        ReplicaSpec.from_times("slow", 4, prefill_token_s=4e-4,
                               decode_step_s=2e-3, link=DCN_LINK),
    ]
    traces = {
        "poisson": poisson_trace(64, rate_rps=200.0, seed=0),
        "bursty": bursty_trace(64, rate_rps=200.0, burst=4, seed=0),
    }
    p99 = {}
    for world in ("electrical", "optical"):
        for tname, trace in traces.items():
            for pol in ("round-robin", "jsq", "greedy", "max-flow"):
                us, st = _timeit(
                    lambda p=pol, w=world, t=trace:
                    ClusterSim(specs, make_policy(p), world=w).run(t))
                p99[(world, tname, pol)] = st.latency_p99_s()
                _row(f"serving/{world}_{tname}_{pol}", us,
                     f"p50_ms={st.latency_p50_s()*1e3:.2f};"
                     f"p99_ms={st.latency_p99_s()*1e3:.2f};"
                     f"tput_tok_s={st.throughput_tok_s():.0f};"
                     f"routed_fast={st.routed['fast']};"
                     f"routed_slow={st.routed['slow']}")
    for world in ("electrical", "optical"):
        rr = p99[(world, "poisson", "round-robin")]
        for pol in ("greedy", "max-flow"):
            assert p99[(world, "poisson", pol)] < rr, (world, pol)
    _row("serving/ordering", 0.0,
         "cost_model_beats_round_robin_p99=True;worlds=electrical+optical")


def roofline():
    from repro.launch.roofline import analyze_dir

    for tag, d in (("baseline", Path("runs/dryrun")),
                   ("optimized", Path("runs/dryrun_opt"))):
        if not d.exists() or not list(d.glob("*__singlepod.json")):
            _row(f"roofline/{tag}/status", 0.0, f"SKIP(no {d} artifacts)")
            continue
        for r in analyze_dir(str(d)):
            _row(f"roofline/{tag}/{r.arch}/{r.shape}", 0.0,
                 f"compute_ms={r.compute_s*1e3:.2f};memory_ms={r.memory_s*1e3:.2f};"
                 f"collective_ms={r.collective_s*1e3:.2f};bottleneck={r.bottleneck};"
                 f"useful={r.useful_ratio:.2f};roofline_frac={r.roofline_fraction:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    table1()
    fig4()
    fig5()
    fig6()
    schedule_level()
    planner()
    collectives()
    perhop()
    ir()
    order_search()
    latency_regime()
    a2a()
    tp_block()
    duality()
    serving()
    roofline()


if __name__ == "__main__":
    main()
